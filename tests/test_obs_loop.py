"""The closed observability loop: windowed histograms, burn-rate SLO
alerts, the flight recorder, and cost-model drift acting on the planner
and admission control.

Everything deterministic runs on an injected fake clock; the acceptance
tests drive real tiny joins through ``JoinQueryService`` and then feed
perturbed measured timings through the audit trail, asserting the drift
detector flags the sticky plan for re-pricing and widens the tenant's
admission margin.
"""
import json
import math

import numpy as np
import pytest

from repro.core import CoProcessor, uniform_relation, unique_relation
from repro.engine import (AdmissionController, BuildTableCache, JoinQuery,
                          JoinQueryService, QueryPlanner, Tenant)
from repro.obs import (CostAudit, DriftDetector, FlightRecorder,
                       MetricsRegistry, PageHinkley, SLObjective, SLOMonitor,
                       validate_dump)


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _tiny_query(qid=1, **kw):
    b = unique_relation(256, seed=1)
    s = uniform_relation(256, key_range=256, seed=2)
    return JoinQuery(build=b, probe=s, query_id=qid, **kw)


# ---------------------------------------------------------------------------
# Time-windowed histograms.
# ---------------------------------------------------------------------------
def test_histogram_time_window_edge_semantics():
    clk = FakeClock()
    reg = MetricsRegistry(histogram_window_s=10.0, clock=clk)
    reg.observe("lat_s", 1.0)          # t=0
    clk.t = 5.0
    reg.observe("lat_s", 2.0)          # t=5
    clk.t = 10.0
    # The t=0 sample's age reached the window exactly: strictly-older-than
    # keeps, so exactly-at-the-edge is OUT.
    s = reg.histogram_summary("lat_s")
    assert s["count"] == 1 and s["min"] == s["max"] == 2.0
    clk.t = 14.9
    assert reg.histogram_summary("lat_s")["count"] == 1
    clk.t = 15.0
    # Fully aged-out window reads as empty, not stale.
    s = reg.histogram_summary("lat_s")
    assert s["count"] == 0 and s["p50"] == 0.0 and s["sum"] == 0.0
    # snapshot() applies the same window.
    assert reg.snapshot()["lat_s"]["count"] == 0


def test_histogram_count_window_unchanged_without_time_window():
    reg = MetricsRegistry(histogram_window=3)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.observe("x", v)
    s = reg.snapshot()["x"]
    assert s["count"] == 3 and s["min"] == 2.0 and s["max"] == 4.0


# ---------------------------------------------------------------------------
# SLO burn-rate monitor on deterministic counter streams.
# ---------------------------------------------------------------------------
def _monitor(reg, clk, **obj_kw):
    obj = SLObjective("deadline", good="deadline_hits",
                      bad="deadline_misses", target=0.75,
                      fast_window_s=60.0, slow_window_s=300.0,
                      burn_threshold=2.0, min_events=8, **obj_kw)
    return SLOMonitor(reg, [obj], clock=clk)


def test_burn_rate_fires_and_clears_single_transition():
    clk, reg = FakeClock(), MetricsRegistry()
    mon = _monitor(reg, clk)
    mon.evaluate(force=True)                     # baseline sample at t=0
    # 12 events, 8 misses: error rate 0.667 / budget 0.25 = burn 2.67.
    for _ in range(4):
        reg.inc("deadline_hits", tenant="gold")
    for _ in range(8):
        reg.inc("deadline_misses", tenant="gold")
    clk.t = 1.0
    active = mon.evaluate(force=True)
    keys = {(a["objective"], a["tenant"]) for a in active}
    assert ("deadline", "gold") in keys and ("deadline", "*") in keys
    a = next(x for x in active if x["tenant"] == "gold")
    assert a["burn_fast"] == pytest.approx(8 / 12 / 0.25, rel=1e-3)
    assert a["events_fast"] == 12
    # Re-evaluating while still firing does NOT re-count the alert.
    clk.t = 2.0
    mon.evaluate(force=True)
    assert reg.counter_value("slo_alerts_total") == len(active)
    fires = [e for e in reg.events("slo") if e["action"] == "fire"]
    assert len(fires) == len(active)
    # Good traffic ages the bad window out: alert clears once both
    # windows drop under threshold.
    for _ in range(200):
        reg.inc("deadline_hits", tenant="gold")
    clk.t = 400.0                                # past the slow window
    mon.evaluate(force=True)
    clk.t = 401.0
    assert mon.evaluate(force=True) == []
    resolves = [e for e in reg.events("slo") if e["action"] == "resolve"]
    assert {(e["objective"], e["tenant"]) for e in resolves} == keys


def test_burn_rate_needs_min_events_and_both_windows():
    clk, reg = FakeClock(), MetricsRegistry()
    mon = _monitor(reg, clk)
    mon.evaluate(force=True)
    # 100% errors but only 4 events: under min_events, no alert (tiny
    # denominators make infinite-looking burns out of a blip).
    for _ in range(4):
        reg.inc("deadline_misses", tenant="gold")
    clk.t = 1.0
    assert mon.evaluate(force=True) == []
    # Many events at a healthy error rate: burn < threshold, no alert.
    for _ in range(96):
        reg.inc("deadline_hits", tenant="gold")
    clk.t = 2.0
    assert mon.evaluate(force=True) == []
    assert reg.counter_value("slo_alerts_total") == 0


def test_burn_rate_windows_diverge_fast_spike_slow_quiet():
    """A fresh spike after a long healthy history trips the fast window
    but not the slow one — the multi-window AND suppresses it."""
    clk, reg = FakeClock(), MetricsRegistry()
    mon = _monitor(reg, clk)
    mon.evaluate(force=True)
    for _ in range(400):                         # long healthy history
        reg.inc("deadline_hits", tenant="gold")
    for t in range(1, 6):
        clk.t = float(60 * t)
        mon.evaluate(force=True)
    for _ in range(10):                          # fresh spike
        reg.inc("deadline_misses", tenant="gold")
    clk.t = 301.0
    active = mon.evaluate(force=True)
    assert active == []                          # slow window still healthy


# ---------------------------------------------------------------------------
# Flight recorder: rings, triggers, dumps.
# ---------------------------------------------------------------------------
def test_flight_ring_bounds_and_tenant_rings():
    clk = FakeClock()
    fr = FlightRecorder(capacity=4, tenant_capacity=2, clock=clk)
    for i in range(6):
        fr.record_admission("degrade", tenant=f"t{i % 2}", query_id=i)
    assert len(fr) == 4
    bundle = fr.dump("manual")
    assert validate_dump(bundle)
    assert [r["query_id"] for r in bundle["records"]] == [2, 3, 4, 5]
    assert [r["query_id"] for r in bundle["tenants"]["t0"]] == [2, 4]
    assert bundle["counts"]["admission"] == 6     # counts survive eviction


def test_flight_shed_storm_dump_and_cooldown(tmp_path):
    clk = FakeClock()
    fr = FlightRecorder(clock=clk, storm_n=3, storm_window_s=5.0,
                        min_dump_gap_s=30.0, dump_dir=str(tmp_path))
    # Three sheds spread WIDER than the window: no storm.
    for t in (0.0, 3.0, 6.0):
        clk.t = t
        fr.record_admission("shed", tenant="a")
    assert fr.dump_count == 0
    # Three sheds inside the window: storm -> dump written to disk.
    for t in (10.0, 11.0, 12.0):
        clk.t = t
        fr.record_admission("shed", tenant="a")
    assert fr.dump_count == 1 and len(fr.dump_paths) == 1
    with open(fr.dump_paths[0]) as f:
        bundle = json.load(f)
    assert validate_dump(bundle) and bundle["reason"] == "shed_storm"
    # Another storm inside the cooldown stays quiet...
    for t in (13.0, 13.5, 14.0):
        clk.t = t
        fr.record_admission("shed", tenant="a")
    assert fr.dump_count == 1
    # ...and fires again once the gap has passed.
    for t in (50.0, 51.0, 52.0):
        clk.t = t
        fr.record_admission("shed", tenant="a")
    assert fr.dump_count == 2


def test_flight_deadline_miss_burst_triggers_dump():
    class Out:                                    # duck-typed outcome
        def __init__(self, i):
            self.plan = None
            self.timing = None
            self.query_id = i
            self.tag = "t"
            self.tenant = "gold"
            self.queued_s = 0.0
            self.wall_s = 0.01
            self.deadline_hit = False
            self.degraded = False
            self.cache_hit = False

    clk = FakeClock()
    fr = FlightRecorder(clock=clk, burst_n=3, burst_window_s=5.0,
                        min_dump_gap_s=0.0)
    for i in range(3):
        clk.t = float(i)
        fr.record_outcome(Out(i))
    assert fr.dump_count == 1
    assert fr.auto_dumps[-1]["reason"] == "deadline_miss_burst"


def test_service_failure_lands_in_flight_recorder(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=2)
    with svc:
        h = svc.submit_deferred(lambda outs: (_ for _ in ()).throw(
            RuntimeError("boom")), tenant="gold")
        with pytest.raises(RuntimeError):
            h()
        failures = [r for r in svc.flight.dump("t")["records"]
                    if r["kind"] == "failure"]
    assert len(failures) == 1
    f = failures[0]
    assert f["tenant"] == "gold" and "boom" in f["error"]
    # A failure always dumps (in-memory here: no dump_dir configured).
    assert svc.flight.dump_count >= 1 and svc.flight.auto_dumps
    assert validate_dump(svc.flight.auto_dumps[-1])
    assert svc.stats()["flight"]["counts"]["failure"] == 1


# ---------------------------------------------------------------------------
# Page-Hinkley + the drift loop acting on planner and admission.
# ---------------------------------------------------------------------------
def test_page_hinkley_stationary_silent_shift_fires_once():
    ph = PageHinkley(delta=0.05, threshold=0.5, min_samples=8)
    rng = np.random.default_rng(7)
    for _ in range(300):
        assert not ph.update(float(rng.normal(0.0, 0.02)))
    fired = 0
    for _ in range(40):
        if ph.update(float(rng.normal(0.9, 0.02))):
            fired += 1
            ph.reset()
    assert fired == 1                  # the shift, once; then re-armed


def test_drift_detector_acts_flags_and_margins():
    reg = MetricsRegistry()
    flagged, margins = [], {}
    det = DriftDetector(metrics=reg,
                        on_drift=lambda p, s, st: flagged.append((p, s)),
                        on_margin=margins.__setitem__,
                        threshold=0.5, min_samples=4, margin_min_samples=4)
    assert reg.snapshot()["cost_model_staleness"] == 0.0   # pre-seeded
    rec = {"phase": "probe", "scheme": "DD", "tenant": "gold"}
    for _ in range(6):
        det.observe_record({**rec, "ratio": 1.0})
    assert flagged == [] and margins == {}
    for _ in range(12):
        det.observe_record({**rec, "ratio": 3.0})
    assert ("probe", "DD") in flagged
    snap = reg.snapshot()
    assert snap["cost_model_staleness"] >= 1.0
    assert snap["cost_model_drift_events"] >= 1
    # q75 of the mixed ratio window prices the gold margin up.
    assert margins["gold"] == pytest.approx(3.0)
    assert snap["admission_margin{tenant=gold}"] == pytest.approx(3.0)
    det.mark_repriced("probe", "DD")
    assert reg.snapshot()["cost_model_staleness"] == 0.0
    # Bad ratios (None / non-finite / <= 0) are ignored, not crashed on.
    for bad in (None, 0.0, -1.0, float("nan"), float("inf")):
        det.observe_record({**rec, "ratio": bad})


def test_drift_reprices_sticky_plan_and_widens_admission(cp):
    """The acceptance loop: perturb measured phase times through the
    audit trail and watch the sticky plan get flagged for re-pricing and
    the tenant's admission margin widen."""
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0, tenants=[Tenant("gold")])
    svc._ensure_workers = lambda: None
    svc.drift.min_samples = 4

    q = _tiny_query(qid=1, tenant="gold")
    svc.submit(q, block=False)
    qq, enq, _b, _d = svc._queue.get_nowait()
    out = svc.execute(qq, enqueued_at=enq)
    planner = svc.planner
    assert planner._plan_cache, "warm query left no sticky plan"
    assert svc.admission.margin_of("gold") == 1.0
    svc.drift.margin_min_samples = 4

    # Replay the executed plan's phases with 4x-inflated measured times —
    # the audit feed a contention shift would produce.
    pairs = QueryPlanner.phase_pairs(out.plan, out.timing)
    inflated = [(p, s, est, 4.0 * max(est, 1e-4))
                for p, s, est, _ in pairs]
    for i in range(16):
        svc.audit.record(inflated, tenant="gold", query_id=100 + i)

    st = planner.stats()
    assert st["replan_flags"] >= 1
    algo = out.plan.algorithm
    assert any(ver == -1 for sig, (ver, plan) in
               planner._plan_cache.items() if plan.algorithm == algo)
    # The widened margin reached admission pricing.
    assert svc.admission.margin_of("gold") > 1.0
    snap = svc.stats()["metrics"]
    assert snap["cost_model_staleness"] >= 1.0
    assert snap.get("plans_flagged_for_replan", 0) >= 1
    assert any(e for e in svc.metrics.events("drift"))

    # Re-choosing the same shape re-prices through the normal sticky
    # path: the flagged entry is stamped back to the live version.
    planner.choose(build_n=q.build.size, probe_n=q.probe.size,
                   max_out=out.plan.max_out)
    assert any(ver == planner.online.version for sig, (ver, plan) in
               planner._plan_cache.items() if plan.algorithm == algo)
    svc.close()


def test_admission_margin_flips_borderline_decision():
    ac = AdmissionController([Tenant("gold", deadline_s=1.0)],
                             num_workers=1, mode="cost")
    d = ac.decide("gold", est_s=0.6, deadline_s=1.0)
    assert d.action == "admit"
    ac.set_margin("gold", 2.0)
    d = ac.decide("gold", est_s=0.6, deadline_s=1.0)
    assert d.action in ("shed", "degrade")       # 1.2s predicted > 1.0s
    assert ac.margins() == {"gold": 2.0}
    ac.set_margin("gold", 0.5)                   # clamped at 1.0
    assert ac.margin_of("gold") == 1.0


# ---------------------------------------------------------------------------
# Satellites: cache attribution, audit retention.
# ---------------------------------------------------------------------------
def _blob(nbytes: int):
    return {"a": np.zeros(nbytes, dtype=np.uint8)}


def test_cache_eviction_attribution_per_tenant():
    reg = MetricsRegistry()
    cache = BuildTableCache(budget_bytes=1000)
    cache.register_metrics(reg, "cache")
    cache.put("ka", _blob(600), tenant="alice")
    assert cache.get("ka", "alice") is not None
    assert cache.get("kx", "bob") is None
    # Bob's insert pushes Alice's entry out of the shared budget.
    cache.put("kb", _blob(600), tenant="bob")
    snap = reg.snapshot()
    assert snap["cache_hits{kind=table,tenant=alice}"] == 1
    assert snap["cache_misses{kind=table,tenant=bob}"] == 1
    assert snap["cache_evictions{kind=table,tenant=alice}"] == 1
    evs = reg.events("cache_eviction")
    assert len(evs) == 1
    assert evs[0]["evictor"] == "bob" and evs[0]["victim"] == "alice"
    assert evs[0]["kind"] == "table" and evs[0]["nbytes"] == 600
    # The collector view still rides along.
    assert snap["cache"]["evictions"] == 1


def test_audit_bounded_retention_capacity_and_listener():
    audit = CostAudit(max_records=4)
    assert audit.capacity == 4
    seen = []
    audit.add_listener(seen.append)
    audit.add_listener(lambda r: 1 / 0)          # broken listener: ignored
    for i in range(6):
        audit.record([("probe", "DD", 1.0, 2.0)], query_id=i)
    assert len(audit.records()) == 4             # bounded ring
    assert [r["query_id"] for r in audit.records()] == [2, 3, 4, 5]
    assert [r["query_id"] for r in seen] == list(range(6))


def test_service_exposes_loop_collectors(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    svc._ensure_workers = lambda: None
    svc.submit(_tiny_query(qid=1), block=False)
    qq, enq, _b, _d = svc._queue.get_nowait()
    svc.execute(qq, enqueued_at=enq)
    st = svc.stats()
    snap = st["metrics"]
    assert snap["audit_capacity"] == float(svc.audit.capacity) > 0
    assert math.isfinite(snap["cost_model_staleness"])
    assert st["flight"]["records"] >= 1
    assert st["slo"]["objectives"] and st["slo"]["alerts_total"] == 0
    assert "margins" in st["drift"]
    assert snap["query_latency_s{tenant=default}"]["count"] == 1
    svc.close()
