"""Observability: span nesting, Chrome export schema, registry thread
safety, and the predicted-vs-measured cost-model audit.

Tracer tests run on an injected fake clock — fully deterministic; the
service-level tests drive real joins through ``JoinQueryService`` and
validate the trace/metrics/audit the execution left behind.
"""
import threading

import pytest

from repro.core import CoProcessor, uniform_relation, unique_relation
from repro.engine import (JoinQuery, JoinQueryService, QueryPlanner, Tenant)
from repro.obs import (CostAudit, MetricsRegistry, NULL_TRACER, NullTracer,
                       Tracer)


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _tiny_query(qid=1, **kw):
    b = unique_relation(256, seed=1)
    s = uniform_relation(256, key_range=256, seed=2)
    return JoinQuery(build=b, probe=s, query_id=qid, **kw)


# ---------------------------------------------------------------------------
# Tracer: nesting, ambient attributes, lanes, the no-op recorder.
# ---------------------------------------------------------------------------
def test_spans_nest_and_inherit_ambient_attrs_fake_clock():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("query", q_key=7, tenant="gold") as q:
        clk.t = 1.0
        with tr.span("plan"):
            clk.t = 2.0
        q.set(scheme="CG_ss")          # discovered mid-span by planning
        with tr.span("probe", n=99):
            clk.t = 5.0
        clk.t = 6.0
    by_name = {s.name: s for s in tr.spans()}
    assert by_name["query"].t0 == 0.0 and by_name["query"].t1 == 6.0
    assert (by_name["plan"].t0, by_name["plan"].t1) == (1.0, 2.0)
    # Children inherit the ambient keys from the innermost open ancestor —
    # including attributes set mid-span *before* the child opened.
    assert by_name["plan"].attrs["q_key"] == 7
    assert by_name["plan"].attrs["tenant"] == "gold"
    assert "scheme" not in by_name["plan"].attrs
    assert by_name["probe"].attrs["scheme"] == "CG_ss"
    assert by_name["probe"].attrs["n"] == 99
    # Per-query index serves exactly the spans stamped with the key.
    assert {d["name"] for d in tr.spans_for(7)} == {"query", "plan", "probe"}


def test_span_stacks_are_per_thread():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    ready = threading.Event()
    release = threading.Event()

    def worker():
        with tr.span("inner-w", q_key=2):
            ready.set()
            release.wait(10.0)

    with tr.span("outer-main", q_key=1):
        th = threading.Thread(target=worker, name="w0")
        th.start()
        ready.wait(10.0)
        release.set()
        th.join()
    spans = {s.name: s for s in tr.spans()}
    # The worker's span did NOT nest under (or inherit from) main's open
    # span: stacks are thread-local.
    assert spans["inner-w"].attrs["q_key"] == 2
    assert spans["inner-w"].thread == "w0"
    assert spans["outer-main"].thread != "w0"


def test_lane_records_cross_thread_interval_and_clamps():
    tr = Tracer(clock=FakeClock())
    tr.lane("queue", 1.0, 3.0, q_key=4)
    tr.lane("queue", 5.0, 2.0)          # inverted -> clamped to zero-length
    a, b = tr.spans()
    assert a.lane == "queue" and (a.t0, a.t1) == (1.0, 3.0)
    assert b.t1 == b.t0 == 5.0


def test_null_tracer_records_nothing():
    tr = NullTracer()
    with tr.span("x") as sp:
        assert sp is None
    tr.lane("queue", 0.0, 1.0)
    tr.instant("shed")
    assert tr.spans() == [] and tr.chrome_trace() == []
    assert NULL_TRACER.spans() == []


def test_tracer_bounds_span_count():
    tr = Tracer(clock=FakeClock(), max_spans=3)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans()) == 3


# ---------------------------------------------------------------------------
# Chrome trace-event export.
# ---------------------------------------------------------------------------
def _validate_chrome(events):
    """Schema invariants Perfetto relies on: metadata first, timestamps
    sorted and non-negative, X slices properly nested per tid, async
    b/e pairs matched."""
    assert events
    n_meta = 0
    while n_meta < len(events) and events[n_meta]["ph"] == "M":
        n_meta += 1
    meta, rest = events[:n_meta], events[n_meta:]
    assert meta, "thread_name metadata missing"
    assert all(e["ph"] != "M" for e in rest)
    ts = [e["ts"] for e in rest]
    assert all(t >= 0 for t in ts)
    assert ts == sorted(ts)
    named_tids = {e["tid"] for e in meta}
    stacks: dict[int, list] = {}
    begins: dict[int, float] = {}
    for e in rest:
        assert e["pid"] == 1 and e["tid"] in named_tids
        if e["ph"] == "X":
            assert e["dur"] >= 0
            st = stacks.setdefault(e["tid"], [])
            while st and st[-1] <= e["ts"]:
                st.pop()
            for open_end in st:   # every open ancestor contains this span
                assert open_end >= e["ts"] + e["dur"]
            st.append(e["ts"] + e["dur"])
        elif e["ph"] == "b":
            begins[e["id"]] = e["ts"]
        elif e["ph"] == "e":
            assert e["ts"] >= begins.pop(e["id"])
        else:
            raise AssertionError(f"unexpected phase {e['ph']!r}")
    assert not begins, "unclosed async lane intervals"


def test_chrome_trace_schema_fake_clock(tmp_path):
    import json
    clk = FakeClock()
    tr = Tracer(clock=clk)
    clk.t = 10.0                       # non-zero epoch: ts must re-zero
    with tr.span("query", q_key=1):
        with tr.span("plan"):
            clk.t = 11.0
        clk.t = 12.0
    tr.lane("queue", 10.5, 11.5, q_key=1)
    events = tr.chrome_trace()
    _validate_chrome(events)
    # Parent precedes child at the shared start timestamp.
    xs = [e for e in events if e.get("ph") == "X"]
    assert [e["name"] for e in xs] == ["query", "plan"]
    path = tmp_path / "trace.json"
    tr.write_chrome_trace(path)
    with open(path) as f:
        payload = json.load(f)
    assert payload["traceEvents"] == events


def test_chrome_trace_from_live_service(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=2)
    with svc:
        handles = [svc.submit(_tiny_query(qid=i)) for i in range(4)]
        outs = [h() for h in handles]
        root = svc.submit_deferred(lambda o: _tiny_query(qid=10))
        child = svc.submit_deferred(lambda o: _tiny_query(qid=11),
                                    deps=[root])
        outs += [root(), child()]
    events = svc.tracer.chrome_trace()
    _validate_chrome(events)
    names = {e["name"] for e in events if e["ph"] in ("X", "b")}
    # The lifecycle stages all made it into the export.
    assert {"admit", "queue", "query", "plan", "probe"} <= names
    # Every submitted query carries the structured per-outcome trace,
    # and its spans share one correlation key.
    for out in outs:
        assert out.trace, f"query {out.query_id} missing trace"
        keys = {d["attrs"].get("q_key") for d in out.trace}
        assert len(keys) == 1 and None not in keys
        assert {"query", "plan"} <= {d["name"] for d in out.trace}


def test_queue_wait_becomes_async_lane_span(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    svc._ensure_workers = lambda: None
    q = _tiny_query(qid=3)
    svc.submit(q, block=False)
    qq, enq, _box, _done = svc._queue.get_nowait()
    out = svc.execute(qq, enqueued_at=enq)
    lanes = [d for d in out.trace if d["lane"] == "queue"]
    assert len(lanes) == 1 and lanes[0]["name"] == "queue"
    assert lanes[0]["dur_s"] >= 0.0
    # The lane shares the query's correlation key with its thread spans.
    assert lanes[0]["attrs"]["q_key"] == \
        out.trace[-1]["attrs"]["q_key"]


def test_disabled_tracer_leaves_no_outcome_trace(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0, tracer=NULL_TRACER)
    out = svc.execute(_tiny_query(qid=1))
    assert out.trace is None
    assert svc.tracer.spans() == []
    # Metrics and the audit still work with tracing off.
    assert svc.stats()["completed"] == 1
    assert svc.audit.summary()["count"] > 0


# ---------------------------------------------------------------------------
# MetricsRegistry: thread safety, flat snapshots, collectors, events.
# ---------------------------------------------------------------------------
def test_registry_concurrent_increments_are_exact():
    reg = MetricsRegistry()
    n_threads, n_incs = 8, 2000

    def hammer(i):
        for _ in range(n_incs):
            reg.inc("ops", tenant=f"t{i % 2}")
            reg.inc("bytes", 3)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter_value("ops") == n_threads * n_incs
    assert reg.counter_value("bytes") == 3 * n_threads * n_incs
    snap = reg.snapshot()
    assert snap["ops"] == n_threads * n_incs
    assert snap["ops{tenant=t0}"] + snap["ops{tenant=t1}"] == snap["ops"]


def test_registry_snapshot_histograms_gauges_events_collectors():
    reg = MetricsRegistry()
    for v in range(1, 101):
        reg.observe("lat_s", v / 100.0)
    reg.set_gauge("depth", 4)
    reg.event("admission", action="shed", tenant="t", reason="deadline")
    reg.event("admission", action="degrade", tenant="t")
    reg.register_collector("cache", lambda: {"hit_rate": 0.5})
    reg.register_collector("broken", lambda: 1 / 0)
    snap = reg.snapshot()
    h = snap["lat_s"]
    assert h["count"] == 100 and h["min"] == 0.01 and h["max"] == 1.0
    assert h["p50"] == pytest.approx(0.50, abs=0.02)
    assert h["p95"] == pytest.approx(0.95, abs=0.02)
    assert snap["depth"] == 4
    assert snap["cache"] == {"hit_rate": 0.5}
    assert snap["broken"] is None      # a broken collector must not sink it
    sheds = [e for e in reg.events("admission")
             if e.get("action") == "shed"]
    assert sheds == [{"event": "admission", "action": "shed",
                      "tenant": "t", "reason": "deadline"}]


def test_service_stats_is_one_coherent_snapshot(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0,
                           tenants=[Tenant("gold"), Tenant("bronze")])
    svc._ensure_workers = lambda: None
    for i, tenant in enumerate(("gold", "gold", "bronze")):
        svc.submit(_tiny_query(qid=i, tenant=tenant), block=False)
        qq, enq, _b, _d = svc._queue.get_nowait()
        svc.execute(qq, enqueued_at=enq)
    st = svc.stats()
    assert st["admitted"] == st["completed"] == 3
    assert st["tenants"]["gold"]["completed"] == 2
    assert st["tenants"]["bronze"]["admitted"] == 1
    # Component views ride in the same pass.
    assert st["cache"] is not None and st["planner"] is not None
    assert st["metrics"]["prediction_error"]["count"] > 0
    # The attribute API still reads the registry.
    assert svc.completed == 3 and svc.admitted == 3


def test_shed_emits_structured_admission_event(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0,
                           tenants=[Tenant("t", deadline_s=0.01)])
    svc._ensure_workers = lambda: None
    svc._admission_estimate = lambda q: (10.0, 0.5)
    svc._degraded_estimate = lambda q: None
    from repro.engine import Backpressure
    with pytest.raises(Backpressure):
        svc.submit(_tiny_query(qid=9, tenant="t"), block=False)
    evs = svc.metrics.events("admission")
    assert len(evs) == 1
    ev = evs[0]
    assert ev["action"] == "shed" and ev["reason"] == "deadline"
    assert ev["tenant"] == "t" and ev["query_id"] == 9
    assert ev["retry_after_s"] > 0 and ev["predicted_s"] == 10.0
    assert ev["deadline_s"] is not None
    # ... and an instant marker in the trace, inside the admit span.
    names = [s.name for s in svc.tracer.spans()]
    assert names == ["shed", "admit"]


# ---------------------------------------------------------------------------
# Cost-model audit: est_s must come from the EXECUTED plan.
# ---------------------------------------------------------------------------
def test_audit_summary_percentiles():
    audit = CostAudit()
    for m in (1.0, 2.0, 3.0):
        audit.record([("probe", "CG_ss", 1.0, m)], tenant="gold")
    audit.record([("probe", "CG_ss", 0.0, 1.0)])   # est<=0 -> no ratio
    s = audit.summary()
    assert s["count"] == 4
    assert s["phases"]["probe"]["count"] == 3
    assert s["phases"]["probe"]["p50"] == pytest.approx(2.0)
    assert s["tenants"]["gold"]["p95"] == pytest.approx(3.0)


def test_audit_est_matches_executed_degraded_plan(cp):
    """Regression: the audit must price the plan the executor RAN — for a
    deadline-degraded query that is the cheapest plan, not the 10s
    admission-time estimate that triggered the degrade."""
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0,
                           tenants=[Tenant("t", deadline_s=0.5)])
    svc._ensure_workers = lambda: None
    svc._admission_estimate = lambda q: (10.0, 0.5)
    svc._degraded_estimate = lambda q: 1e-4
    q = _tiny_query(qid=21, tenant="t")
    svc.submit(q, block=False)
    assert q.degraded is True
    qq, _enq, _box, _done = svc._queue.get_nowait()
    out = svc.execute(qq)
    recs = [r for r in svc.audit.records() if r["query_id"] == 21]
    assert recs, "executed query left no audit records"
    pairs = QueryPlanner.phase_pairs(out.plan, out.timing)
    assert [(r["phase"], r["scheme"]) for r in recs] == \
        [(p, s) for p, s, _, _ in pairs]
    for rec, (_, _, est_s, measured_s) in zip(recs, pairs):
        assert rec["est_s"] == pytest.approx(est_s)
        assert rec["measured_s"] == pytest.approx(measured_s)
        assert rec["est_s"] < 10.0      # NOT the admission-time estimate
        assert rec["tenant"] == "t"
    # The measured side is the real executed phase time.
    assert {r["phase"] for r in recs} <= set(out.timing.phase_s)
