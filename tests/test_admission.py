"""Multi-tenant SLO admission: two-level scheduler, shed/degrade, deferred
inheritance.  Scheduler tests run on a fake clock — fully deterministic."""
import threading

import pytest

from repro.core import CoProcessor, join_oracle, uniform_relation, \
    unique_relation
from repro.engine import (AdmissionController, Backpressure, JoinQuery,
                          JoinQueryService, QueryPlanner, QueueFull, Tenant,
                          TenantFairQueue, jain_index)


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# TenantFairQueue: the two-level scheduler.
# ---------------------------------------------------------------------------
def test_fair_share_equal_weights_alternates():
    clk = FakeClock()
    q = TenantFairQueue(clock=clk)
    for i in range(3):
        q.put(f"a{i}", tenant="a", est_s=1.0)
        q.put(f"b{i}", tenant="b", est_s=1.0)
    order = [q.get_nowait() for _ in range(6)]
    # Equal weights, equal costs: strict alternation (a first on the
    # deterministic name tie-break).
    assert order == ["a0", "b0", "a1", "b1", "a2", "b2"]


def test_fair_share_respects_weights_2_to_1():
    clk = FakeClock()
    weights = {"heavy": 2.0, "light": 1.0}
    q = TenantFairQueue(clock=clk, weight_fn=lambda t: weights[t])
    for i in range(8):
        q.put(f"h{i}", tenant="heavy", est_s=1.0)
        q.put(f"l{i}", tenant="light", est_s=1.0)
    first6 = [q.get_nowait() for _ in range(6)]
    # Cost-weighted stride: the weight-2 tenant receives twice the
    # estimated service seconds of the weight-1 tenant.
    assert sum(x.startswith("h") for x in first6) == 4
    assert sum(x.startswith("l") for x in first6) == 2


def test_fair_share_is_cost_weighted_not_count_weighted():
    clk = FakeClock()
    q = TenantFairQueue(clock=clk)
    # Tenant a's queries are 4x the cost of b's: b gets ~4 queries per a
    # query, equalizing estimated seconds, not counts.
    for i in range(2):
        q.put(f"a{i}", tenant="a", est_s=4.0)
    for i in range(8):
        q.put(f"b{i}", tenant="b", est_s=1.0)
    first5 = [q.get_nowait() for _ in range(5)]
    assert sum(x.startswith("b") for x in first5) == 4


def test_edf_within_tenant_and_no_deadline_sorts_last():
    clk = FakeClock()
    q = TenantFairQueue(clock=clk)
    q.put("best-effort", tenant="a", est_s=1.0)           # no deadline
    q.put("late", tenant="a", deadline_at=100.0, est_s=1.0)
    q.put("urgent", tenant="a", deadline_at=5.0, est_s=1.0)
    assert [q.get_nowait() for _ in range(3)] == \
        ["urgent", "late", "best-effort"]


def test_no_deadline_entries_keep_aged_priority_order():
    clk = FakeClock()
    q = TenantFairQueue(clock=clk, aging_s=5.0)
    q.put("old-low", priority=0, tenant="a", est_s=1.0)
    clk.t = 20.0         # old-low aged 20s/5s = +4 > fresh priority 2
    q.put("fresh-high", priority=2, tenant="a", est_s=1.0)
    assert q.get_nowait() == "old-low"
    assert q.get_nowait() == "fresh-high"


def test_idle_tenant_does_not_bank_virtual_time():
    clk = FakeClock()
    q = TenantFairQueue(clock=clk)
    for i in range(4):
        q.put(f"a{i}", tenant="a", est_s=1.0)
    for _ in range(4):
        q.get_nowait()                   # a's vtime advances to 4.0
    # b arrives only now: clamped to the active floor, not credited 4s of
    # idle time that would starve a.
    q.put("b0", tenant="b", est_s=1.0)
    q.put("a4", tenant="a", est_s=1.0)
    first = q.get_nowait()
    assert first == "b0"                 # b serves first (vtime 4.0 tie,
    q.put("b1", tenant="b", est_s=1.0)   # name tie-break is a... but b
    # arrived at the clamped floor; after one b the lanes alternate:
    assert {q.get_nowait(), q.get_nowait()} == {"a4", "b1"}


def test_fifo_mode_ignores_tenants_and_deadlines():
    clk = FakeClock()
    q = TenantFairQueue(clock=clk, fifo=True)
    q.put("first", tenant="a", deadline_at=100.0, est_s=5.0)
    q.put("second", tenant="b", deadline_at=1.0, est_s=0.1)
    q.put("third", tenant="a", deadline_at=0.5, est_s=0.1)
    assert [q.get_nowait() for _ in range(3)] == \
        ["first", "second", "third"]


def test_queue_backlog_tracking_and_capacity():
    import queue as stdq
    clk = FakeClock()
    q = TenantFairQueue(maxsize=2, clock=clk)
    q.put("x", tenant="a", est_s=1.5)
    q.put("y", tenant="b", est_s=0.5)
    assert q.backlog_s("a") == pytest.approx(1.5)
    assert q.backlog_s() == pytest.approx(2.0)
    assert len(q) == 2
    with pytest.raises(stdq.Full):
        q.put("z", tenant="a", block=False)
    q.get_nowait()
    assert q.backlog_s() < 2.0


# ---------------------------------------------------------------------------
# AdmissionController: admit / degrade / shed pricing.
# ---------------------------------------------------------------------------
def test_decide_admits_when_prediction_fits():
    ac = AdmissionController([Tenant("t")], num_workers=2)
    d = ac.decide("t", est_s=0.1, deadline_s=1.0)
    assert d.action == "admit" and d.predicted_s == pytest.approx(0.1)


def test_decide_degrades_when_cheapest_plan_fits():
    ac = AdmissionController([Tenant("t")], num_workers=2)
    d = ac.decide("t", est_s=5.0, deadline_s=1.0,
                  degraded_est_fn=lambda: 0.5)
    assert d.action == "degrade"
    assert d.predicted_s == pytest.approx(0.5)


def test_decide_sheds_with_retry_after_hint():
    ac = AdmissionController([Tenant("t")], num_workers=1)
    d = ac.decide("t", est_s=5.0, deadline_s=1.0,
                  degraded_est_fn=lambda: 4.0, inflight_s=2.0)
    assert d.action == "shed"
    # wait 2.0 + cheapest 4.0 - deadline 1.0 = 5.0s until it could fit.
    assert d.retry_after_s == pytest.approx(5.0)


def test_decide_charges_fair_share_of_backlog():
    ac = AdmissionController([Tenant("t", weight=1.0)], num_workers=1)
    # Tenant holds half the active weight: its 1s backlog drains at half
    # the service rate, so the charge doubles.
    d = ac.decide("t", est_s=0.0, deadline_s=None,
                  tenant_backlog_s=1.0, active_weight=2.0)
    assert d.predicted_s == pytest.approx(2.0)


def test_decide_budget_caps_service_rate():
    ac = AdmissionController(
        [Tenant("t", c_budget=0.25, g_budget=0.25)], num_workers=1)
    # Sole active tenant (share would be 1.0) but budgeted to a quarter
    # of each group: backlog drains 4x slower.
    d = ac.decide("t", est_s=0.0, deadline_s=None, tenant_backlog_s=1.0)
    assert d.predicted_s == pytest.approx(4.0)


def test_fifo_mode_never_sheds():
    ac = AdmissionController([Tenant("t")], num_workers=1, mode="fifo")
    d = ac.decide("t", est_s=100.0, deadline_s=0.01)
    assert d.action == "admit"


def test_jain_index():
    assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1.0 / 3.0)
    assert jain_index([]) == 1.0


def test_backpressure_is_queue_full_and_structured():
    e = Backpressure("nope", reason="deadline", tenant="t", query_id=7,
                     retry_after_s=0.5, predicted_s=2.0, deadline_s=1.0)
    assert isinstance(e, QueueFull)
    d = e.to_dict()
    assert d["reason"] == "deadline" and d["retry_after_s"] == 0.5
    assert d["query_id"] == 7


# ---------------------------------------------------------------------------
# Service-level shed / degrade / inheritance.
# ---------------------------------------------------------------------------
def _tiny_query(qid=1, **kw):
    b = unique_relation(256, seed=1)
    s = uniform_relation(256, key_range=256, seed=2)
    return JoinQuery(build=b, probe=s, query_id=qid, **kw)


def test_service_sheds_hopeless_query_with_backpressure(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0,
                           tenants=[Tenant("t", deadline_s=0.01)])
    svc._ensure_workers = lambda: None
    svc._admission_estimate = lambda q: (10.0, 0.5)   # hopeless
    svc._degraded_estimate = lambda q: None
    with pytest.raises(Backpressure) as ei:
        svc.submit(_tiny_query(tenant="t"), block=False)
    err = ei.value
    assert err.reason == "deadline" and err.retry_after_s > 0
    st = svc.stats()
    assert st["shed"] == 1 and st["tenants"]["t"]["shed"] == 1
    assert st["admitted"] == 0


def test_service_degrades_instead_of_shedding(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0,
                           tenants=[Tenant("t", deadline_s=0.5)])
    svc._ensure_workers = lambda: None
    svc._admission_estimate = lambda q: (10.0, 0.5)
    svc._degraded_estimate = lambda q: 1e-4
    q = _tiny_query(tenant="t")
    svc.submit(q, block=False)
    assert q.degraded is True
    assert svc.stats()["degraded"] == 1
    # The degraded query still computes the correct join.
    qq, _enq, _box, _done = svc._queue.get_nowait()
    out = svc.execute(qq)
    exp = join_oracle(qq.build, qq.probe)
    got = out.result.valid_pairs()
    assert got.shape == exp.shape and (got == exp).all()
    assert out.degraded is True


def test_preadmitted_skips_shed_decision(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0,
                           tenants=[Tenant("t", deadline_s=0.01)])
    svc._ensure_workers = lambda: None
    svc._admission_estimate = lambda q: (10.0, 0.5)
    svc._degraded_estimate = lambda q: None
    svc.submit(_tiny_query(tenant="t"), block=False, preadmitted=True)
    assert svc.stats()["shed"] == 0 and svc.stats()["admitted"] == 1


def test_tenant_default_deadline_class_applies(cp):
    clk = FakeClock()
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0, clock=clk,
                           tenants=[Tenant("t", deadline_s=2.0)])
    svc._ensure_workers = lambda: None
    q = _tiny_query(tenant="t")
    clk.t = 10.0
    svc.submit(q, block=False)
    assert q.deadline_at == pytest.approx(12.0)


def test_deferred_stage_inherits_tenant_and_deadline(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0,
                           tenants=[Tenant("gold", deadline_s=60.0)])
    root = svc.submit_deferred(
        lambda outs: _tiny_query(qid=1, tenant="gold", deadline_s=60.0))
    child = svc.submit_deferred(lambda outs: _tiny_query(qid=2),
                                deps=[root])
    root_out, child_out = root(), child()
    assert root_out.tenant == "gold"
    assert child_out.tenant == "gold"
    assert child_out.deadline_at == root_out.deadline_at
    assert child_out.deadline_at is not None


def test_deferred_stages_respect_capacity_bound(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0, max_deferred=2)
    gate = threading.Event()

    def blocked_dep(timeout=None):
        gate.wait(10.0)
        return svc.execute(_tiny_query(qid=99))

    h1 = svc.submit_deferred(lambda outs: _tiny_query(qid=1),
                             deps=[blocked_dep])
    h2 = svc.submit_deferred(lambda outs: _tiny_query(qid=2),
                             deps=[blocked_dep])
    # Both slots held by stages pinned on their deps: the third deferred
    # submit must push back instead of spawning an unbounded thread.
    with pytest.raises(Backpressure) as ei:
        svc.submit_deferred(lambda outs: _tiny_query(qid=3), block=False)
    assert ei.value.reason == "queue_full"
    assert svc.stats()["rejected"] == 1
    gate.set()
    assert h1().result.count >= 0 and h2().result.count >= 0
    # Slots released: a new deferred stage is admitted again.
    assert svc.submit_deferred(
        lambda outs: _tiny_query(qid=4))().result.count >= 0


def test_deferred_failure_counted_without_workers(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)

    def boom(outs):
        raise RuntimeError("stage exploded")

    h = svc.submit_deferred(boom)
    with pytest.raises(RuntimeError, match="stage exploded"):
        h()
    assert svc.stats()["failed"] == 1
    # A dependent stage failing on the *propagated* error does not count
    # the same failure twice.
    h2 = svc.submit_deferred(lambda outs: _tiny_query(), deps=[h])
    with pytest.raises(RuntimeError, match="stage exploded"):
        h2()
    assert svc.stats()["failed"] == 1


def test_worker_path_counts_failure_once(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=1)
    bad = _tiny_query(qid=5)
    bad.build = None                       # breaks inside execute()
    h = svc.submit(bad)
    with pytest.raises(Exception):
        h()
    assert svc.stats()["failed"] == 1
    svc.close()


def test_open_loop_traffic_is_deterministic_and_tagged():
    from repro.engine import open_loop
    kw = dict(rate_qps=50.0, mix="uniform", arrivals="burst",
              tenant_mix=(("a", 1.0), ("b", 1.0)), hot_tenant="a",
              hot_skew=0.3, deadlines={"a": 0.5}, base_tuples=512, seed=7)
    ev1 = open_loop(12, **kw)
    ev2 = open_loop(12, **kw)
    assert [e.at_s for e in ev1] == [e.at_s for e in ev2]
    assert [e.tenant for e in ev1] == [e.tenant for e in ev2]
    assert all(e.query.deadline_s == 0.5 for e in ev1 if e.tenant == "a")
    assert all(e.query.deadline_s is None for e in ev1 if e.tenant == "b")
    # Monotone arrival times; hot skew shifts mass toward tenant a.
    ts = [e.at_s for e in ev1]
    assert all(t2 >= t1 for t1, t2 in zip(ts, ts[1:]))
    assert sum(e.tenant == "a" for e in ev1) >= \
        sum(e.tenant == "b" for e in ev1)
