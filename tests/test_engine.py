"""Concurrent join-query engine: cache, planner, service, feedback.

Runs in degraded single-device mode like test_coprocess.py; the real
8-device overlap is exercised by ``benchmarks.run --only engine_throughput``.
"""
import numpy as np
import pytest

from repro.core import (CoProcessor, Timing, join_oracle, uniform_relation,
                        unique_relation)
from repro.core.calibrate import OnlineUnitCosts
from repro.core.hash_table import default_num_buckets
from repro.engine import (BuildTableCache, JoinQuery, JoinQueryService,
                          QueryPlanner, WorkloadGenerator, make_workload,
                          relation_fingerprint, table_nbytes)


@pytest.fixture(scope="module")
def cp():
    return CoProcessor()


@pytest.fixture(scope="module")
def planner():
    return QueryPlanner(delta=0.25)


# ---------------------------------------------------------------------------
# Build-table cache.
# ---------------------------------------------------------------------------

def test_fingerprint_is_content_based():
    a = uniform_relation(512, seed=3)
    b = uniform_relation(512, seed=3)      # regenerated, same content
    c = uniform_relation(512, seed=4)
    assert relation_fingerprint(a, 64) == relation_fingerprint(b, 64)
    assert relation_fingerprint(a, 64) != relation_fingerprint(c, 64)
    # Different table geometry is a different cache line.
    assert relation_fingerprint(a, 64) != relation_fingerprint(a, 128)


def test_cache_hit_and_lru_eviction():
    from repro.core import build_hash_table
    tables = {i: build_hash_table(unique_relation(256, seed=i), 64)
              for i in range(3)}
    nbytes = table_nbytes(tables[0])
    cache = BuildTableCache(budget_bytes=2 * nbytes)  # room for two
    cache.put("t0", tables[0])
    cache.put("t1", tables[1])
    assert cache.get("t0") is tables[0]    # t0 is now MRU
    cache.put("t2", tables[2])             # evicts LRU = t1
    assert cache.get("t1") is None
    assert cache.get("t0") is tables[0]
    assert cache.get("t2") is tables[2]
    st = cache.stats()
    assert st["evictions"] == 1 and st["entries"] == 2
    assert st["bytes"] <= st["budget_bytes"]
    # A table bigger than the whole budget is refused, not cached.
    assert not BuildTableCache(budget_bytes=8).put("big", tables[0])


# ---------------------------------------------------------------------------
# Planner: scheme + algorithm choice.
# ---------------------------------------------------------------------------

def test_planner_small_prefers_shj_large_prefers_phj(planner):
    small = planner.choose(4096, 4096, max_out=8192)
    big = planner.choose(1 << 24, 1 << 24, max_out=1024)
    assert small.algorithm == "shj"        # partitioning is pure overhead
    assert big.algorithm == "phj"          # table >> cache: pay to partition
    assert big.schedule is not None and sum(big.schedule) > 0


def test_planner_apu_model_avoids_cpu_only(planner):
    # The APU model's GPU wins the hash-heavy steps >15x (Fig. 4), so the
    # sweep must never land on CPU_ONLY.
    plan = planner.choose(65536, 65536, max_out=65536)
    assert plan.scheme != "CPU_ONLY"
    assert plan.est_s > 0


def test_planner_cached_skips_build_cost(planner):
    cold = planner.choose(65536, 65536, max_out=65536, cached=False)
    hot = planner.choose(65536, 65536, max_out=65536, cached=True)
    assert hot.cached and hot.est_build_s == 0.0
    assert hot.est_s < cold.est_s


def test_planner_load_aware_tiebreak():
    # Symmetric devices + a heavily loaded C-group: the chosen plan should
    # lean on the G-group (low c_share), and vice versa.
    from repro.core.calibrate import APU_CPU
    pl = QueryPlanner(APU_CPU, APU_CPU, delta=0.25, allow_phj=False)
    on_g = pl.choose(16384, 16384, max_out=16384, c_load=10.0, g_load=0.0)
    on_c = pl.choose(16384, 16384, max_out=16384, c_load=0.0, g_load=10.0)
    assert on_g.c_share < on_c.c_share


def test_online_unit_costs_ewma():
    o = OnlineUnitCosts(alpha=0.5)
    assert o.scale_for("x") == 1.0
    o.observe("x", est_s=1.0, measured_s=4.0)    # first: full correction
    assert o.scale_for("x") == pytest.approx(4.0)
    o.observe("x", est_s=1.0, measured_s=4.0)    # still 4x off: EWMA step
    assert o.scale_for("x") == pytest.approx(8.0)  # 4 * 4**0.5
    o.observe("x", est_s=1.0, measured_s=1.0)    # fixed point
    assert o.scale_for("x") == pytest.approx(8.0)
    o.observe("x", est_s=0.0, measured_s=1.0)    # degenerate: ignored
    assert o.scale_for("x") == pytest.approx(8.0)


def test_feedback_shifts_estimates():
    pl = QueryPlanner(delta=0.25, allowed_schemes=("DD",), allow_phj=False)
    plan = pl.choose(8192, 8192, max_out=16384)
    before = plan.est_s
    t = Timing()
    t.phase_s = {"build": 100.0 * plan.est_build_s or 1.0,
                 "probe": 100.0 * plan.est_probe_s or 1.0}
    pl.observe(plan, t)
    after = pl.choose(8192, 8192, max_out=16384).est_s
    assert after > before                       # estimates track reality
    assert pl.online.scale_for("shj_probe:DD") > 1.0


# ---------------------------------------------------------------------------
# Service: correctness, cache reuse, admission.
# ---------------------------------------------------------------------------

def test_service_executes_mixed_workload_correctly(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    wl = make_workload("mixed", num_queries=8, base_tuples=2048, seed=5)
    for q in wl:
        out = svc.execute(q)
        exp = join_oracle(q.build, q.probe)
        got = out.result.valid_pairs()
        assert got.shape == exp.shape and (got == exp).all(), \
            (q.tag, out.plan.algorithm, out.plan.scheme)
        assert out.timing.wall_s > 0
    assert svc.stats()["completed"] == len(wl)


def test_service_cache_hit_skips_build(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25,
                                                       allow_phj=False),
                           num_workers=0)
    b = unique_relation(2048, seed=1)
    s1 = uniform_relation(4096, key_range=2048, seed=2)
    s2 = uniform_relation(4096, key_range=2048, seed=3)
    out1 = svc.execute(JoinQuery(build=b, probe=s1, query_id=1))
    out2 = svc.execute(JoinQuery(build=b, probe=s2, query_id=2))
    assert not out1.cache_hit and out2.cache_hit
    assert out2.timing.phase_s["build"] == 0.0
    assert (out2.result.valid_pairs() == join_oracle(b, s2)).all()
    assert svc.cache.stats()["hits"] == 1


def test_service_threaded_run_matches_oracle(cp):
    with JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                          num_workers=2) as svc:
        wl = make_workload("hot_table", num_queries=6, base_tuples=1024,
                           seed=9)
        outs = svc.run(wl)
        assert [o.query_id for o in outs] == [q.query_id for q in wl]
        for q, o in zip(wl, outs):
            assert (o.result.valid_pairs()
                    == join_oracle(q.build, q.probe)).all()
        assert svc.stats()["cache"]["hits"] > 0   # hot pool recurs


def test_admission_rejects_when_full(cp):
    from repro.engine import QueueFull
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           max_queue=1, num_workers=0)
    # No workers drain the queue: the second non-blocking submit must bounce.
    b = unique_relation(256, seed=1)
    s = uniform_relation(256, key_range=256, seed=2)
    svc._ensure_workers = lambda: None
    svc.submit(JoinQuery(build=b, probe=s, query_id=1), block=False)
    with pytest.raises(QueueFull):
        svc.submit(JoinQuery(build=b, probe=s, query_id=2), block=False)
    assert svc.stats()["rejected"] == 1


def test_outcome_and_timing_to_dict(cp):
    import json
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    q = make_workload("uniform", num_queries=1, base_tuples=512, seed=1)[0]
    out = svc.execute(q)
    d = out.to_dict()
    json.dumps(d)                               # fully serializable
    assert d["timing"]["phase_s"] and d["matches"] >= 0
    # Bench rollups segment latency by plan type straight off the dict.
    assert d["algorithm"] in ("shj", "phj")
    for key in ("scheme", "cache_hit", "partition_cache_hit", "priority",
                "schedule", "table_mode"):
        assert key in d


# ---------------------------------------------------------------------------
# Priority admission (aged; starvation-free).
# ---------------------------------------------------------------------------

def test_priority_queue_orders_by_priority_then_fifo():
    from repro.engine import PriorityAgingQueue
    now = [0.0]
    pq = PriorityAgingQueue(maxsize=8, aging_s=1000.0, clock=lambda: now[0])
    pq.put("low", priority=0)
    pq.put("hi-a", priority=5)
    pq.put("hi-b", priority=5)
    pq.put("mid", priority=2)
    # Highest priority first; FIFO inside the level; lowest last.
    assert [pq.get() for _ in range(4)] == ["hi-a", "hi-b", "mid", "low"]


def test_priority_queue_aging_prevents_starvation():
    from repro.engine import PriorityAgingQueue
    now = [0.0]
    pq = PriorityAgingQueue(maxsize=64, aging_s=1.0, clock=lambda: now[0])
    pq.put("starved", priority=0)
    # A steady stream of fresh high-priority arrivals keeps winning...
    for i in range(3):
        now[0] = float(i)
        pq.put(f"hi-{i}", priority=3)
        assert pq.get() == f"hi-{i}"
    # ...until the old query has aged past the priority gap: effective
    # priority 0 + 4.0/1.0 = 4 beats a fresh 3 + 0.5/1.0 = 3.5.
    now[0] = 3.5
    pq.put("hi-late", priority=3)
    now[0] = 4.0
    assert pq.get() == "starved"
    assert pq.get() == "hi-late"


def test_priority_queue_full_and_empty():
    import queue as _q
    from repro.engine import PriorityAgingQueue
    pq = PriorityAgingQueue(maxsize=1)
    pq.put("a")
    with pytest.raises(_q.Full):
        pq.put("b", block=False)
    assert pq.get() == "a"
    with pytest.raises(_q.Empty):
        pq.get(timeout=0.01)


def test_service_runs_priorities(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=2)
    b = unique_relation(512, seed=1)
    s = uniform_relation(512, key_range=512, seed=2)
    outs = svc.run([JoinQuery(build=b, probe=s, query_id=i, priority=p)
                    for i, p in enumerate((0, 3, 1))])
    assert [o.priority for o in outs] == [0, 3, 1]
    assert all((o.result.valid_pairs() == join_oracle(b, s)).all()
               for o in outs)
    svc.close()


# ---------------------------------------------------------------------------
# Partition-layout cache (PHJ build-side reuse).
# ---------------------------------------------------------------------------

def _phj_planner():
    # Tiny cache + harsh random-access penalty: PHJ wins even at 4k tuples.
    pl = QueryPlanner(delta=0.25, cache_bytes=1 << 10, rand_penalty=8.0,
                      phj_overhead_s=0.0)
    assert pl.choose(4096, 4096, max_out=8192).algorithm == "phj"
    return pl


def test_partition_cache_entries_and_stats():
    from repro.engine import BuildTableCache, partition_layout_key
    layout = uniform_relation(256, seed=1)
    cache = BuildTableCache(budget_bytes=1 << 20)
    key = partition_layout_key("fp", (3, 2))
    assert key != partition_layout_key("fp", (2, 3))
    assert cache.get_partition(key) is None     # counted separately
    cache.put_partition(key, layout)
    assert cache.get_partition(key) is layout
    st = cache.stats()
    assert st["partition_hits"] == 1 and st["partition_misses"] == 1
    assert st["partition_puts"] == 1 and st["partition_hit_rate"] == 0.5
    assert st["hits"] == 0 and st["misses"] == 0    # table counters untouched


def test_service_phj_partition_reuse(cp):
    svc = JoinQueryService(cp=cp, planner=_phj_planner(), num_workers=0)
    b = uniform_relation(4096, seed=3)
    exp = {}
    outs = []
    for i, seed in enumerate((4, 5)):
        s = uniform_relation(4096, key_range=4096, seed=seed)
        exp[i] = join_oracle(b, s)
        outs.append(svc.execute(JoinQuery(build=b, probe=s, query_id=i,
                                          max_out=4 * 4096 + 1024)))
    assert outs[0].plan.algorithm == "phj"
    assert not outs[0].partition_cache_hit and outs[1].partition_cache_hit
    assert outs[1].timing.notes.get("build_parts_reused")
    for i, o in enumerate(outs):
        assert (o.result.valid_pairs() == exp[i]).all()
    st = svc.cache.stats()
    assert st["partition_hits"] == 1 and st["partition_misses"] == 1


# ---------------------------------------------------------------------------
# Deferred submission (pipeline stages with dependencies).
# ---------------------------------------------------------------------------

def test_submit_deferred_chains_queries(cp):
    import jax.numpy as jnp
    from repro.core import Relation
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=2)
    b = unique_relation(1024, seed=1)
    s = uniform_relation(1024, key_range=1024, seed=2)
    h1 = svc.submit(JoinQuery(build=b, probe=s, query_id=1))
    seen = {}

    def make_second(outcomes):
        (o1,) = outcomes
        c = int(o1.result.count)
        # Probe the first stage's matched build rids (gather convention).
        probe = Relation(jnp.arange(c, dtype=jnp.int32),
                         jnp.asarray(o1.result.build_rid[:c]))
        return JoinQuery(build=b, probe=probe, query_id=2,
                         max_out=2 * c + 64)

    h2 = svc.submit_deferred(make_second, deps=[h1],
                             finalize=lambda o: seen.update(done=o.query_id),
                             priority=2)
    out2 = h2()
    assert seen["done"] == 2 and out2.priority == 2
    assert int(out2.result.count) == int(h1().result.count)
    svc.close()


def test_submit_deferred_propagates_dep_failure(cp):
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=2)

    def failing_wait(timeout=None):
        raise RuntimeError("upstream stage failed")

    h = svc.submit_deferred(lambda outs: None, deps=[failing_wait])
    with pytest.raises(RuntimeError, match="upstream stage failed"):
        h()
    svc.close()


# ---------------------------------------------------------------------------
# Workload generator.
# ---------------------------------------------------------------------------

def test_workload_scenarios_and_mixes():
    gen = WorkloadGenerator(1024, seed=0)
    for name in ("uniform", "zipf", "selectivity", "hot_table"):
        q = getattr(gen, name)()
        assert q.build.size >= 256 and q.probe.size >= 256
        assert q.max_out > 0 and q.query_id > 0
    wl = make_workload("mixed", num_queries=20, base_tuples=512, seed=2)
    tags = {q.tag.split("_")[0] for q in wl}
    assert len(wl) == 20 and len(tags) >= 2     # genuinely mixed
    # Determinism: same seed, same stream.
    wl2 = make_workload("mixed", num_queries=20, base_tuples=512, seed=2)
    assert [q.tag for q in wl] == [q.tag for q in wl2]
    assert all(np.asarray(a.probe.key).tobytes()
               == np.asarray(b.probe.key).tobytes()
               for a, b in zip(wl, wl2))


def test_hot_table_stream_recurs_fingerprints():
    wl = make_workload("hot_table", num_queries=8, base_tuples=512, seed=4)
    fps = [relation_fingerprint(q.build, default_num_buckets(q.build.size))
           for q in wl]
    assert len(set(fps)) < len(fps)             # pool recurrence


# ---------------------------------------------------------------------------
# Service-layer regressions: max_out=0, queued_s accounting, wrap32 sig.
# ---------------------------------------------------------------------------

def test_explicit_max_out_zero_is_respected(cp):
    # An explicit max_out=0 (legitimate for expected-empty probes) must
    # not be silently replaced by the heuristic 4*|S|+1024 capacity.
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    b = unique_relation(512, seed=1)
    s = uniform_relation(512, key_range=512, seed=2)
    out = svc.execute(JoinQuery(build=b, probe=s, max_out=0, query_id=1))
    assert out.plan.max_out == 0
    assert int(out.result.count) == 0


def test_queued_s_reported_on_worker_path(cp):
    import time
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    q = make_workload("uniform", num_queries=1, base_tuples=512, seed=9)[0]
    # Direct path: no queue, honestly 0.0.
    assert svc.execute(q).queued_s == 0.0
    # Enqueue stamp in the past: the wait is accounted, not hardcoded 0.
    out = svc.execute(q, enqueued_at=time.perf_counter() - 0.25)
    assert out.queued_s >= 0.25


def test_groupby_feedback_signature_includes_wrap32(cp):
    from repro.engine import GroupByQuery
    from repro.core import uniform_relation as _ur
    svc = JoinQueryService(cp=cp, planner=QueryPlanner(delta=0.25),
                           num_workers=0)
    keys = _ur(512, key_range=16, seed=3)
    vals = np.ones(512, np.int32)
    svc.execute(GroupByQuery(keys=keys, values=vals, query_id=1,
                             wrap32=True))
    sigs = {s for s in svc._observed_sigs if s[0] == "groupby"}
    assert all(len(s) == 4 for s in sigs)       # wrap32 is in the sig
    svc.execute(GroupByQuery(keys=keys, values=vals, query_id=2,
                             wrap32=False))
    sigs2 = {s for s in svc._observed_sigs if s[0] == "groupby"}
    # The wide run after a wrap32 run of the same size is a FRESH
    # signature (different executable), not "warmed".
    assert len(sigs2) == len(sigs) + 1
