"""Multi-join query pipeline: IR, optimizer pricing, pipelined execution.

Every executed plan is checked against the pure-NumPy reference
(``reference_execute``), which folds the joins in textual order — so these
tests double as permutation-invariance checks whenever the optimizer picks
a different order.
"""
import numpy as np
import pytest

from repro.core import uniform_relation
from repro.engine import JoinQueryService, QueryPlanner
from repro.queries import (Filter, Join, JoinOrderOptimizer,
                           PipelineExecutor, Query, Table, make_chain_query,
                           make_star_query, reference_execute, rows_array)


@pytest.fixture(scope="module")
def planner():
    return QueryPlanner(delta=0.25)


@pytest.fixture(scope="module")
def optimizer(planner):
    return JoinOrderOptimizer(planner)


def run_pipeline(query, physical=None, optimizer=None, **svc_kw):
    svc = JoinQueryService(planner=QueryPlanner(delta=0.25),
                           num_workers=svc_kw.pop("num_workers", 2),
                           **svc_kw)
    with PipelineExecutor(service=svc, optimizer=optimizer) as ex:
        return ex.run(query, physical), svc


# ---------------------------------------------------------------------------
# IR.
# ---------------------------------------------------------------------------

def test_relation_gather():
    rel = uniform_relation(64, seed=0)
    idx = np.array([3, 3, 0, 63], dtype=np.int32)
    got = rel.gather(idx)
    assert (np.asarray(got.rid) == np.asarray(rel.rid)[idx]).all()
    assert (np.asarray(got.key) == np.asarray(rel.key)[idx]).all()


def test_filter_mask_and_estimate():
    col = np.arange(100, dtype=np.int32)
    f = Filter("a", 10, 30)
    assert f.mask(col).sum() == 20
    assert f.estimate(col) == pytest.approx(0.2)
    annotated = Filter("a", 10, 30, selectivity=0.5)
    assert annotated.estimate(col) == 0.5      # annotation wins over range


def test_table_filter_and_stats():
    t = Table("t", {"id": np.arange(100), "a": np.arange(100) % 10},
              filters=(Filter("a", 0, 3),))
    ft = t.filtered()
    assert ft.size == 30
    assert set(t.qualified()) == {"t.id", "t.a"}
    assert t.est_rows() == pytest.approx(100 * 0.3, rel=0.2)
    assert t.ndv_est("a") <= 10


def test_query_validation():
    with pytest.raises(ValueError):
        Table("bad", {"a": np.arange(3), "b": np.arange(4)})
    t = Table("t", {"id": np.arange(8)})
    with pytest.raises(ValueError):
        Query(tables={"t": t}, joins=(Join("t", "id", "u", "id"),))
    with pytest.raises(ValueError):
        Query(tables={"t": t}, joins=(Join("t", "nope", "t", "id"),))
    with pytest.raises(ValueError):
        Query(tables={"t": t}, joins=(), aggregate=("median",))
    with pytest.raises(ValueError, match="sum over unknown column"):
        Query(tables={"t": t}, joins=(), aggregate=("sum", "X.m"))
    with pytest.raises(ValueError, match="sum over unknown column"):
        Query(tables={"t": t}, joins=(), aggregate=("sum", "id"))  # no dot
    # Disconnected join graphs fail at construction, not mid-pipeline.
    u, v = Table("u", {"id": np.arange(8)}), Table("v", {"id": np.arange(8)})
    with pytest.raises(ValueError, match="disconnected"):
        Query(tables={"t": t, "u": u, "v": v},
              joins=(Join("t", "id", "u", "id"),))
    with pytest.raises(ValueError, match="disconnected"):
        Query(tables={"t": t, "u": u}, joins=())


def test_negative_join_keys_rejected(optimizer):
    t = Table("t", {"k": np.array([-6, 1, 2], dtype=np.int32)})
    u = Table("u", {"k": np.array([0, 1, 2], dtype=np.int32)})
    q = Query(tables={"t": t, "u": u}, joins=(Join("t", "k", "u", "k"),))
    with pytest.raises(ValueError, match="negative join-key"):
        run_pipeline(q, optimizer=optimizer)


def test_cycle_edge_is_residual_filter(optimizer):
    # Two edges between the same pair of tables: the second becomes an
    # equality filter on the joined component, matching the reference.
    rng = np.random.default_rng(41)
    a = Table("a", {"k1": rng.integers(0, 16, 256).astype(np.int32),
                    "k2": rng.integers(0, 4, 256).astype(np.int32)})
    b = Table("b", {"id": np.arange(16, dtype=np.int32),
                    "id2": (np.arange(16, dtype=np.int32) % 4)})
    q = Query(tables={"a": a, "b": b},
              joins=(Join("a", "k1", "b", "id"),
                     Join("a", "k2", "b", "id2")), aggregate=("count",))
    ref_rows, ref_agg = reference_execute(q)
    assert ref_agg > 0                      # the filter keeps something
    for order in optimizer.enumerate_orders(q):
        physical = optimizer.price_order(q, order)
        assert len(physical.stages) == 1 and len(physical.residuals) == 1
        res, _ = run_pipeline(q, physical, optimizer=optimizer)
        assert res.aggregate == ref_agg
        assert (res.rows_array() == ref_rows).all()


def test_self_edge_filters_base_table(optimizer):
    t = Table("t", {"x": np.array([0, 1, 2, 3], dtype=np.int32),
                    "y": np.array([0, 1, 0, 3], dtype=np.int32)})
    q = Query(tables={"t": t}, joins=(Join("t", "x", "t", "y"),),
              aggregate=("count",))
    ref_rows, ref_agg = reference_execute(q)
    res, _ = run_pipeline(q, optimizer=optimizer)
    assert res.aggregate == ref_agg == 3    # rows 0, 1, 3
    assert (res.rows_array() == ref_rows).all()


# ---------------------------------------------------------------------------
# Executor vs the NumPy reference.
# ---------------------------------------------------------------------------

def test_star_pipeline_matches_reference(optimizer):
    q = make_star_query(2048, [256, 256, 256],
                        selectivities=[0.1, None, 0.5], seed=3,
                        aggregate=("sum", "F.m"))
    ref_rows, ref_agg = reference_execute(q)
    res, svc = run_pipeline(q, optimizer=optimizer)
    assert res.aggregate == ref_agg
    got = res.rows_array()
    assert got.shape == ref_rows.shape and (got == ref_rows).all()
    assert len(res.outcomes) == 3
    assert svc.stats()["completed"] == 3


def test_chain_pipeline_matches_reference(optimizer):
    q = make_chain_query([1024, 512, 256], seed=5, aggregate=("count",))
    ref_rows, ref_agg = reference_execute(q)
    res, _ = run_pipeline(q, optimizer=optimizer)
    assert res.aggregate == ref_agg == res.rows
    assert (res.rows_array() == ref_rows).all()


def test_empty_intermediate_pipeline(optimizer):
    # A filter that keeps nothing: downstream stages see empty inputs and
    # the pipeline must still run to a correct (empty) result.
    q = make_star_query(512, [64, 64], selectivities=[None, None], seed=7)
    d0 = q.tables["D0"]
    q.tables["D0"] = d0.with_filters(Filter("a", 2000, 2001))  # empty
    ref_rows, ref_agg = reference_execute(q)
    assert ref_agg == 0
    res, _ = run_pipeline(q, optimizer=optimizer)
    assert res.rows == 0 and res.aggregate == 0
    assert res.rows_array().shape == ref_rows.shape


def test_no_join_query(optimizer):
    t = Table("t", {"id": np.arange(32, dtype=np.int32)})
    q = Query(tables={"t": t}, joins=(), aggregate=("count",))
    res, _ = run_pipeline(q, optimizer=optimizer)
    assert res.rows == 32 and res.aggregate == 32 and not res.outcomes


def test_pipeline_reuses_build_side_caches(optimizer):
    # The same star query replayed through one service: second run's
    # build sides are resident (hash tables or partition layouts).
    q = make_star_query(1024, [256, 256], seed=11)
    svc = JoinQueryService(planner=QueryPlanner(delta=0.25), num_workers=2)
    with PipelineExecutor(service=svc, optimizer=optimizer) as ex:
        first = ex.run(q)
        second = ex.run(q)
    assert first.aggregate == second.aggregate
    st = svc.cache.stats()
    assert st["hits"] + st["partition_hits"] > 0


# ---------------------------------------------------------------------------
# Optimizer: ordering + permutation invariance.
# ---------------------------------------------------------------------------

def test_optimizer_prefers_selective_dimension_first(optimizer):
    q = make_star_query(8192, [512, 512, 512],
                        selectivities=[0.02, None, None], seed=13)
    chosen = optimizer.optimize(q)
    assert chosen.stages[0].join.right == "D0"   # most selective first
    worst = optimizer.worst_order(q)
    assert chosen.est_total_s <= worst.est_total_s


def test_all_orders_same_rows(optimizer):
    q = make_star_query(512, [128, 128], selectivities=[0.3, None], seed=17)
    ref_rows, _ = reference_execute(q)
    arrays = []
    for order in optimizer.enumerate_orders(q):
        res, _ = run_pipeline(q, optimizer.price_order(q, order),
                              optimizer=optimizer)
        arrays.append(res.rows_array())
    for got in arrays:
        assert got.shape == ref_rows.shape and (got == ref_rows).all()


def test_greedy_order_for_many_relations(planner):
    opt = JoinOrderOptimizer(planner, exhaustive_joins=2)
    q = make_chain_query([512, 256, 128, 64], seed=19)   # 3 joins > 2
    physical = opt.optimize(q)
    assert len(physical.stages) == 3
    baseline = opt.price_order(q, q.joins)
    assert physical.est_total_s <= baseline.est_total_s
    res, _ = run_pipeline(q, physical, optimizer=opt)
    ref_rows, ref_agg = reference_execute(q)
    assert res.aggregate == ref_agg and (res.rows_array() == ref_rows).all()


def test_physical_plan_annotations(optimizer):
    q = make_star_query(2048, [256, 256], seed=23)
    physical = optimizer.optimize(q)
    for s in physical.stages:
        assert s.plan.algorithm in ("shj", "phj")
        assert s.plan.scheme in ("CPU_ONLY", "GPU_ONLY", "OL", "DD", "PL")
        assert s.est_build > 0 and s.est_probe > 0
    d = physical.to_dict()
    assert len(d["stages"]) == 2 and d["est_total_s"] > 0
    assert physical.describe()


# ---------------------------------------------------------------------------
# Property-based: pricing dominance + permutation invariance (small inputs).
# ---------------------------------------------------------------------------

def _check_pricing_dominance(opt, fact, dims, sel, seed):
    q = make_star_query(fact, dims,
                        selectivities=[sel] + [None] * (len(dims) - 1),
                        seed=seed)
    chosen = opt.optimize(q)
    textual = opt.price_order(q, q.joins)
    # The chosen order never prices worse than the left-deep textual
    # order (which is always among the candidates).
    assert chosen.est_total_s <= textual.est_total_s + 1e-12


def _check_invariance(opt, seed, sel):
    q = make_star_query(256, [64, 64], selectivities=[sel, None], seed=seed)
    ref_rows, ref_agg = reference_execute(q)
    svc = JoinQueryService(planner=QueryPlanner(delta=0.25), num_workers=0)
    with PipelineExecutor(service=svc, optimizer=opt) as ex:
        for order in opt.enumerate_orders(q):
            res = ex.run(q, opt.price_order(q, order))
            assert res.aggregate == ref_agg
            got = res.rows_array()
            assert got.shape == ref_rows.shape
            assert (got == ref_rows).all(), order


def test_property_based_optimizer_and_invariance(optimizer):
    """Hypothesis-driven when available; a deterministic sweep over the
    same domain otherwise (the property must hold either way)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for fact, dims, sel, seed in (
                (512, [64, 256], None, 0), (2048, [256, 1024], 0.05, 1),
                (16384, [64, 1024, 256], 0.5, 2),
                (2048, [256, 64, 64], None, 3), (512, [1024, 64], 0.05, 4)):
            _check_pricing_dominance(optimizer, fact, dims, sel, seed)
        for seed, sel in ((0, None), (1, 0.25)):
            _check_invariance(optimizer, seed, sel)
        return

    @settings(max_examples=15, deadline=None)
    @given(fact=st.sampled_from([512, 2048, 16384]),
           dims=st.lists(st.sampled_from([64, 256, 1024]), min_size=2,
                         max_size=3),
           sel=st.sampled_from([None, 0.05, 0.5]),
           seed=st.integers(0, 99))
    def check_pricing(fact, dims, sel, seed):
        _check_pricing_dominance(optimizer, fact, dims, sel, seed)

    check_pricing()

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 99), sel=st.sampled_from([None, 0.25]))
    def check_invariance(seed, sel):
        _check_invariance(optimizer, seed, sel)

    check_invariance()


# ---------------------------------------------------------------------------
# Device-resident hand-off: view-chain correctness vs host vs reference.
# ---------------------------------------------------------------------------

def _run_handoff(query, physical, optimizer, handoff):
    svc = JoinQueryService(planner=QueryPlanner(delta=0.25), num_workers=2)
    with PipelineExecutor(service=svc, optimizer=optimizer,
                          handoff=handoff) as ex:
        res = ex.run(query, physical)
        stats = svc.stats()
    return res, stats


def _dup_key_star(seed):
    """A star whose build sides carry duplicate keys (fan-out > 1)."""
    rng = np.random.default_rng(seed)
    f = Table("F", {"fk0": rng.integers(0, 32, 512).astype(np.int32),
                    "fk1": rng.integers(0, 16, 512).astype(np.int32),
                    "m": rng.integers(0, 50, 512).astype(np.int32)})
    d0 = Table("D0", {"id": rng.integers(0, 32, 96).astype(np.int32),
                      "a": rng.integers(0, 1000, 96).astype(np.int32)})
    d1 = Table("D1", {"id": rng.integers(0, 16, 48).astype(np.int32),
                      "b": rng.integers(0, 9, 48).astype(np.int32)})
    return Query(tables={"F": f, "D0": d0, "D1": d1},
                 joins=(Join("F", "fk0", "D0", "id"),
                        Join("F", "fk1", "D1", "id")),
                 aggregate=("count",))


def _check_handoff_parity(optimizer, query):
    """Every enumerated order, both hand-off paths, vs the reference."""
    ref_rows, ref_agg = reference_execute(query)
    for order in optimizer.enumerate_orders(query):
        physical = optimizer.price_order(query, order)
        for mode in ("device", "host"):
            res, stats = _run_handoff(query, physical, optimizer, mode)
            assert res.aggregate == ref_agg, (order, mode)
            got = res.rows_array()
            assert got.shape == ref_rows.shape and (got == ref_rows).all(), \
                (order, mode)
            if mode == "device":
                assert res.host_bytes_moved == 0
                assert stats["host_bytes_moved"] == 0


def test_handoff_parity_star_chain_properties(optimizer):
    """Hypothesis-driven when available; a deterministic sweep over the
    same domain otherwise.  Covers empty intermediates (a filter keeping
    nothing), duplicate build keys, and selective/unselective mixes."""
    def check(fact, dims, sel, seed):
        q = make_star_query(fact, dims,
                            selectivities=[sel] + [None] * (len(dims) - 1),
                            seed=seed)
        _check_handoff_parity(optimizer, q)

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for fact, dims, sel, seed in ((256, [64, 64], None, 0),
                                      (512, [64, 32], 0.1, 1),
                                      (512, [128, 64], 0.5, 2)):
            check(fact, dims, sel, seed)
    else:
        @settings(max_examples=6, deadline=None)
        @given(fact=st.sampled_from([256, 512, 1024]),
               dims=st.lists(st.sampled_from([32, 64, 128]), min_size=2,
                             max_size=2),
               sel=st.sampled_from([None, 0.1, 0.5]),
               seed=st.integers(0, 99))
        def check_prop(fact, dims, sel, seed):
            check(fact, dims, sel, seed)

        check_prop()

    # Duplicate build keys: every order, both paths.
    _check_handoff_parity(optimizer, _dup_key_star(5))
    # Empty intermediate: a filter that keeps nothing.
    q = make_star_query(256, [64, 64], selectivities=[None, None], seed=7)
    q.tables["D0"] = q.tables["D0"].with_filters(Filter("a", 5000, 5001))
    _check_handoff_parity(optimizer, q)
    # Chain shape (the probe side threads through every stage).
    _check_handoff_parity(optimizer, make_chain_query([256, 128, 64],
                                                      seed=9))


def test_deep_chain_triggers_depth_cap_flattening(optimizer):
    """A 6-table chain drives rid chains past CHAIN_DEPTH_CAP: the
    device path must flatten on device and stay row-identical."""
    from repro.core.relation import CHAIN_DEPTH_CAP
    q = make_chain_query([256, 192, 160, 128, 96, 64], seed=13,
                         aggregate=None)
    assert len(q.joins) > CHAIN_DEPTH_CAP
    ref_rows, _ = reference_execute(q)
    physical = optimizer.price_order(q, q.joins)
    res, stats = _run_handoff(q, physical, optimizer, "device")
    got = res.rows_array()
    assert got.shape == ref_rows.shape and (got == ref_rows).all()
    assert stats["host_bytes_moved"] == 0


def test_index_chain_depth_cap():
    import jax.numpy as jnp
    from repro.core.relation import IndexChain
    col = np.arange(100, dtype=np.int32) * 3
    rng = np.random.default_rng(0)
    chain = IndexChain()
    expect = col
    for _ in range(6):
        idx = rng.integers(0, expect.shape[0], 24).astype(np.int32)
        chain = chain.extend(jnp.asarray(idx), cap=2)
        expect = expect[idx]
        assert chain.depth <= 2        # cap flattens eagerly
        assert (np.asarray(chain.gather(col)) == expect).all()


def test_host_bytes_accounting_modes(optimizer):
    """Fused: 0 intermediate bytes; host: the gather/re-upload volume,
    surfaced through QueryOutcome.to_dict and service stats."""
    q = make_star_query(512, [128, 128], selectivities=[0.3, None], seed=19,
                        aggregate=("sum", "F.m"))
    physical = optimizer.optimize(q)
    dev, dev_stats = _run_handoff(q, physical, optimizer, "device")
    host, host_stats = _run_handoff(q, physical, optimizer, "host")
    assert dev.host_bytes_moved == 0 and dev_stats["host_bytes_moved"] == 0
    assert host.host_bytes_moved > 0
    assert host_stats["host_bytes_moved"] == host.host_bytes_moved
    for o in host.outcomes:
        assert o.to_dict()["host_bytes_moved"] == o.host_bytes_moved
    assert host.to_dict()["host_bytes_moved"] == host.host_bytes_moved
    assert dev.aggregate == host.aggregate


def test_grouped_sink_consumes_view(optimizer):
    """Group-by sink over the fused path: single-column keys hand over
    device arrays (0 intermediate bytes); wide sums are exact; wrap32
    reproduces the legacy wrap against the reference."""
    q = make_star_query(1024, [128], selectivities=[0.5], seed=23,
                        aggregate=("sum", "F.m"), group_by=("F.g",))
    q.tables["F"].columns["m"][:] = 2**30        # would wrap int32
    ref_rows, _ = reference_execute(q)
    res, stats = _run_handoff(q, optimizer.optimize(q), optimizer, "device")
    assert (res.rows_array() == ref_rows).all()
    assert stats["host_bytes_moved"] == 0
    qw = Query(tables=q.tables, joins=q.joins, aggregate=q.aggregate,
               group_by=q.group_by, wrap32=True)
    ref_w, _ = reference_execute(qw)
    res_w, _ = _run_handoff(qw, optimizer.optimize(qw), optimizer, "device")
    assert (res_w.rows_array() == ref_w).all()
    assert not (ref_w == ref_rows).all()         # the wrap is real here


# ---------------------------------------------------------------------------
# Star workload generation.
# ---------------------------------------------------------------------------

def test_workload_star_queries():
    from repro.engine import WorkloadGenerator
    gen = WorkloadGenerator(1024, seed=31)
    stars = [gen.star() for _ in range(4)]
    for s in stars:
        assert len(s.joins) >= 2 and "F" in s.tables
    # Recurring dimension pool: at least one dim table object is shared.
    dim_ids = [id(t.columns["id"]) for s in stars for n, t in
               s.tables.items() if n != "F"]
    assert len(set(dim_ids)) < len(dim_ids)
    # Determinism: same seed, same stream shape.
    gen2 = WorkloadGenerator(1024, seed=31)
    stars2 = [gen2.star() for _ in range(4)]
    assert [s.describe() for s in stars] == [s.describe() for s in stars2]


def test_workload_star_executes_correctly():
    from repro.engine import WorkloadGenerator
    gen = WorkloadGenerator(512, seed=37)
    q = gen.star(num_dims=2)
    ref_rows, ref_agg = reference_execute(q)
    res, _ = run_pipeline(q)
    assert res.aggregate == ref_agg
    assert (res.rows_array() == ref_rows).all()
